"""Model assembly: templates, forward, loss, KV-cache decode for all ten
assigned architectures (dense / MoE / RWKV6 / hybrid / enc-dec / VLM).

Layers are *stacked* along a leading ``layers`` axis and executed with
``lax.scan`` (optionally ``jax.checkpoint``-rematerialized), so the lowered
HLO is O(one layer) regardless of depth — required for the 512-device
dry-run compiles and the production-sane choice anyway.  Architectures with
heterogeneous layers are split into homogeneous *groups* (e.g. DeepSeek-V2:
1 dense block + 59 MoE blocks; Seamless: encoder stack + decoder stack);
gemma-style local/global interleave stays a single group with a per-layer
``is_global`` scan input selecting the attention window.

Public API:
  model_template(cfg)                       -> ParamDef tree
  forward(cfg, params, batch)               -> (logits, aux)  [train/prefill]
  loss_fn(cfg, params, batch)               -> (scalar, metrics)
  init_cache(cfg, batch, max_len)           -> cache pytree
  decode_step(cfg, params, cache, tok, idx) -> (logits, new cache)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.nn import attention as attn
from repro.nn import moe as moe_lib
from repro.nn import ssm as ssm_lib
from repro.nn.layers import (
    embed,
    embedding_template,
    make_norm,
    mlp,
    mlp_template,
    unembed,
    unembed_template,
)
from repro.nn.param import ParamDef

PyTree = Any


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def stack_layers(template: PyTree, n: int) -> PyTree:
    def leaf(pd: ParamDef) -> ParamDef:
        return ParamDef((n,) + pd.shape, ("layers",) + pd.axes, init=pd.init,
                        scale=pd.scale, dtype=pd.dtype)

    return jax.tree.map(leaf, template, is_leaf=lambda x: isinstance(x, ParamDef))


def _norm(cfg: ArchConfig):
    return make_norm(cfg.norm_kind)


def _attn_template(cfg: ArchConfig):
    if cfg.attn_kind == "mla":
        return attn.mla_template(
            cfg.d_model, cfg.n_heads,
            kv_lora=cfg.kv_lora_rank, q_lora=cfg.q_lora_rank,
            qk_nope=cfg.qk_nope_head_dim, qk_rope=cfg.qk_rope_head_dim,
            v_head=cfg.v_head_dim, dtype=cfg.dtype)
    return attn.gqa_template(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim_, dtype=cfg.dtype)


def _self_attention(cfg: ArchConfig, params, x, positions, *, window):
    if cfg.attn_kind == "mla":
        return attn.mla_attention(params, x, positions,
                                  qk_nope=cfg.qk_nope_head_dim,
                                  qk_rope=cfg.qk_rope_head_dim,
                                  rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk)
    return attn.gqa_attention(params, x, positions, causal=True, window=window,
                              rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk)


def _resolve_window(cfg: ArchConfig, is_global=None):
    """None = unmasked-causal; is_global is a traced bool for local_global."""
    if cfg.attn_kind == "full" or cfg.attn_kind == "mla":
        return None
    if cfg.attn_kind == "swa":
        return cfg.window
    if cfg.attn_kind == "local_global":
        big = jnp.int32(2**30)
        return jnp.where(is_global, big, jnp.int32(cfg.window))
    return None


# --------------------------------------------------------------------------
# block templates
# --------------------------------------------------------------------------


def dense_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    return {
        "ln1": nt(cfg.d_model, cfg.dtype),
        "attn": _attn_template(cfg),
        "ln2": nt(cfg.d_model, cfg.dtype),
        "mlp": mlp_template(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated, dtype=cfg.dtype),
    }


def moe_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    return {
        "ln1": nt(cfg.d_model, cfg.dtype),
        "attn": _attn_template(cfg),
        "ln2": nt(cfg.d_model, cfg.dtype),
        "moe": moe_lib.moe_template(cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                                    n_shared=cfg.n_shared_experts,
                                    gated=cfg.mlp_gated, dtype=cfg.dtype),
    }


def rwkv_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    hs = min(64, cfg.d_model)
    t = ssm_lib.rwkv6_template(cfg.d_model, cfg.d_ff, head_size=hs, dtype=cfg.dtype)
    return {"ln1": nt(cfg.d_model, cfg.dtype), "ln2": nt(cfg.d_model, cfg.dtype), **t}


def hymba_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    return {
        "ln1": nt(cfg.d_model, cfg.dtype),
        "attn": _attn_template(cfg),
        "mamba": ssm_lib.mamba_template(cfg.d_model, n_state=cfg.ssm_state, dtype=cfg.dtype),
        "ln_a": nt(cfg.d_model, cfg.dtype),     # per-path output norms (Hymba fusion)
        "ln_s": nt(cfg.d_model, cfg.dtype),
        "ln2": nt(cfg.d_model, cfg.dtype),
        "mlp": mlp_template(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated, dtype=cfg.dtype),
    }


def encoder_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    return {
        "ln1": nt(cfg.d_model, cfg.dtype),
        "attn": _attn_template(cfg),
        "ln2": nt(cfg.d_model, cfg.dtype),
        "mlp": mlp_template(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated, dtype=cfg.dtype),
    }


def decoder_xattn_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    return {
        "ln1": nt(cfg.d_model, cfg.dtype),
        "attn": _attn_template(cfg),
        "ln_x": nt(cfg.d_model, cfg.dtype),
        "xattn": attn.gqa_template(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim_, dtype=cfg.dtype),
        "ln2": nt(cfg.d_model, cfg.dtype),
        "mlp": mlp_template(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated, dtype=cfg.dtype),
    }


# --------------------------------------------------------------------------
# model template
# --------------------------------------------------------------------------


def layer_groups(cfg: ArchConfig):
    """Ordered (name, count, template_fn) describing homogeneous stacks.

    local_global archs (gemma3) are regrouped into period-sized
    *super-blocks* — scan over n_super blocks, each unrolling `period`
    layers with a STATIC window per sub-layer (local..local, global) — so
    the banded-attention path applies to local layers (§Perf).  Layer order
    is exactly preserved; a non-multiple tail stays as its own stack.
    """
    if cfg.attn_kind == "local_global" and cfg.local_global_period > 1:
        p = cfg.local_global_period
        n_super, tail = divmod(cfg.n_layers, p)
        groups = []
        if n_super:
            groups.append(("lg_super", n_super,
                           lambda c: stack_layers(dense_block_template(c), p)))
        if tail:
            groups.append(("lg_tail", tail, dense_block_template))
        return groups
    if cfg.is_encoder_decoder:
        return [("enc", cfg.enc_layers, encoder_block_template),
                ("dec", cfg.n_layers, decoder_xattn_block_template)]
    if cfg.is_moe:
        groups = []
        if cfg.n_dense_layers:
            groups.append(("dense", cfg.n_dense_layers, dense_block_template))
        groups.append(("moe", cfg.n_layers - cfg.n_dense_layers, moe_block_template))
        return groups
    if cfg.ssm_kind == "rwkv6":
        return [("rwkv", cfg.n_layers, rwkv_block_template)]
    if cfg.hybrid:
        return [("hymba", cfg.n_layers, hymba_block_template)]
    return [("dense", cfg.n_layers, dense_block_template)]


def model_template(cfg: ArchConfig) -> Dict[str, Any]:
    nt, _ = _norm(cfg)
    t: Dict[str, Any] = {
        "embed": embedding_template(cfg.vocab_size, cfg.d_model, cfg.dtype),
        "final_norm": nt(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        t["unembed"] = unembed_template(cfg.d_model, cfg.vocab_size, cfg.dtype)
    if cfg.modality in ("audio", "vlm"):
        # projector from stub frontend embeddings into d_model
        t["frontend_proj"] = {
            "w": ParamDef((cfg.frontend_dim, cfg.d_model), (None, "fsdp"),
                          init="scaled", dtype=cfg.dtype)
        }
    t["groups"] = {
        name: stack_layers(tmpl_fn(cfg), count)
        for name, count, tmpl_fn in layer_groups(cfg)
        if count > 0
    }
    return t


# --------------------------------------------------------------------------
# block apply (train / prefill)
# --------------------------------------------------------------------------


def _block_apply(cfg: ArchConfig, group: str, params, x, positions, is_global,
                 window_override=None):
    """Returns (x, aux_scalar)."""
    _, norm = _norm(cfg)
    aux = jnp.float32(0.0)
    if group == "rwkv":
        y, _ = ssm_lib.rwkv6_time_mix(params["time_mix"], norm(params["ln1"], x),
                                      head_size=min(64, cfg.d_model))
        x = x + y
        y, _ = ssm_lib.rwkv6_channel_mix(params["channel_mix"], norm(params["ln2"], x))
        return x + y, aux

    window = window_override if window_override is not None else _resolve_window(cfg, is_global)
    if window_override == "full":
        window = None
    if group == "hymba":
        h = norm(params["ln1"], x)
        a = attn.gqa_attention(params["attn"], h, positions, causal=True,
                               window=window, rope_theta=cfg.rope_theta,
                               chunk=cfg.attn_chunk)
        s, _ = ssm_lib.mamba_apply(params["mamba"], h)
        x = x + 0.5 * (norm(params["ln_a"], a) + norm(params["ln_s"], s))
        x = x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act)
        return x, aux

    if group == "enc":
        h = norm(params["ln1"], x)
        a = attn.gqa_attention(params["attn"], h, positions, causal=False,
                               rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk)
        x = x + a
        x = x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act)
        return x, aux

    # dense / moe / dec share the self-attention sublayer
    h = norm(params["ln1"], x)
    a = _self_attention(cfg, params["attn"], h, positions, window=window)
    x = x + a
    if group == "dec":
        raise ValueError("decoder blocks need encoder context; use _dec_block_apply")
    if group == "moe":
        y, aux = moe_lib.moe_apply(params["moe"], norm(params["ln2"], x),
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor, act=cfg.act)
        return x + y, aux
    return x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act), aux


def _dec_block_apply(cfg: ArchConfig, params, x, positions, enc_out, enc_positions):
    _, norm = _norm(cfg)
    h = norm(params["ln1"], x)
    x = x + _self_attention(cfg, params["attn"], h, positions, window=None)
    h = norm(params["ln_x"], x)
    x = x + attn.gqa_attention(params["xattn"], h, positions, kv_x=enc_out,
                               kv_positions=enc_positions, use_rope=False,
                               chunk=cfg.attn_chunk)
    return x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act), jnp.float32(0.0)


def _scan_group(block_fn, stacked_params, x, xs_extra=None, *, remat: bool):
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def body(h, scan_in):
        p, extra = scan_in
        h2, aux = fn(p, h, extra)
        return h2, aux

    n = jax.tree.leaves(stacked_params)[0].shape[0]
    if xs_extra is None:
        xs_extra = jnp.zeros((n,), jnp.int32)
    x, auxs = lax.scan(body, x, (stacked_params, xs_extra))
    return x, jnp.sum(auxs)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def _embed_inputs(cfg: ArchConfig, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ frontend) embeddings. Returns (x, positions)."""
    x = embed(params["embed"], batch["inputs"])
    if cfg.modality in ("audio", "vlm") and not cfg.is_encoder_decoder:
        fe = jnp.einsum("bfd,de->bfe", batch["frontend"].astype(x.dtype),
                        params["frontend_proj"]["w"])
        x = jnp.concatenate([fe, x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions


def _is_global_xs(cfg: ArchConfig, count: int):
    return None   # local_global is handled structurally (lg_super groups)


def forward(cfg: ArchConfig, params, batch, *, remat: bool = False):
    """Training / prefill forward. Returns (logits, aux dict)."""
    aux_total = jnp.float32(0.0)

    if cfg.is_encoder_decoder:
        # encoder over stub frontend embeddings
        fe = jnp.einsum("bfd,de->bfe", batch["frontend"],
                        params["frontend_proj"]["w"])
        enc_pos = jnp.arange(fe.shape[1])
        enc_x, aux = _scan_group(
            lambda p, h, e: _block_apply(cfg, "enc", p, h, enc_pos, e),
            params["groups"]["enc"], fe, remat=remat)
        aux_total += aux
        _, norm = _norm(cfg)
        enc_out = enc_x

        x = embed(params["embed"], batch["inputs"])
        pos = jnp.arange(x.shape[1])
        x, aux = _scan_group(
            lambda p, h, e: _dec_block_apply(cfg, p, h, pos, enc_out, enc_pos),
            params["groups"]["dec"], x, remat=remat)
        aux_total += aux
    else:
        x, pos = _embed_inputs(cfg, params, batch)
        for name, count, _ in layer_groups(cfg):
            if count == 0:
                continue
            if name == "lg_super":
                period = cfg.local_global_period

                def super_apply(p, h, e):
                    a = jnp.float32(0.0)
                    for i in range(period):
                        sub = jax.tree.map(lambda t: t[i], p)
                        win = "full" if cfg.layer_is_global(i) else cfg.window
                        h, ai = _block_apply(cfg, "dense", sub, h, pos, None,
                                             window_override=win)
                        a += ai
                    return h, a

                x, aux = _scan_group(super_apply, params["groups"][name], x,
                                     remat=remat)
            elif name == "lg_tail":
                x, aux = _scan_group(
                    lambda p, h, e: _block_apply(cfg, "dense", p, h, pos, None,
                                                 window_override=cfg.window),
                    params["groups"][name], x, remat=remat)
            else:
                xs = _is_global_xs(cfg, count)
                x, aux = _scan_group(
                    lambda p, h, e, _n=name: _block_apply(cfg, _n, p, h, pos, e),
                    params["groups"][name], x, xs_extra=xs, remat=remat)
            aux_total += aux

    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
    else:
        logits = unembed(params["unembed"], x)
    return logits, {"moe_aux": aux_total}


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = False):
    logits, aux = forward(cfg, params, batch, remat=remat)
    tgt = batch["targets"]
    if cfg.modality in ("audio", "vlm") and not cfg.is_encoder_decoder:
        # frontend positions carry no LM targets: score only the text tail
        logits = logits[:, -tgt.shape[1]:, :]
    loss = cross_entropy(logits, tgt, batch.get("mask"))
    total = loss + cfg.router_aux_weight * aux["moe_aux"]
    metrics = {"ce": loss, "moe_aux": aux["moe_aux"]}
    return total, metrics


# --------------------------------------------------------------------------
# decode (serve): KV caches / recurrent state per layer group
# --------------------------------------------------------------------------


def _block_cache_init(cfg: ArchConfig, group: str, batch: int, max_len: int):
    dt = cfg.dtype
    if group == "lg_super":
        single = attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim_, dtype=dt)
        p = cfg.local_global_period
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (p,) + x.shape), single)
    if group == "lg_tail":
        return attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim_, dtype=dt)
    if group == "rwkv":
        hs = min(64, cfg.d_model)
        return ssm_lib.rwkv6_init_state(batch, cfg.d_model, head_size=hs, dtype=dt)
    if group == "hymba":
        return {
            "attn": attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim_, dtype=dt),
            "mamba": ssm_lib.mamba_init_state(batch, cfg.d_model, cfg.ssm_state, dt),
        }
    if cfg.attn_kind == "mla":
        return attn.mla_init_cache(batch, max_len, cfg.kv_lora_rank, cfg.qk_rope_head_dim, dt)
    return attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim_, dtype=dt)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0):
    """Stacked (leading layer axis) cache pytree per group."""
    cache: Dict[str, Any] = {}
    for name, count, _ in layer_groups(cfg):
        if count == 0:
            continue
        if name == "enc":
            continue  # encoder runs once at prefill; no cache
        single = _block_cache_init(cfg, name, batch, max_len)
        cache[name] = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), single)
        if name == "dec":
            # precomputed encoder output consumed by every cross-attn layer
            cache["enc_out"] = jnp.zeros((batch, enc_len, cfg.d_model), cfg.dtype)
    return cache


def _block_decode(cfg: ArchConfig, group: str, params, cache, x, cur_index, is_global,
                  window_override=None):
    """One token through one block. Returns (x, new_cache)."""
    _, norm = _norm(cfg)
    if group == "rwkv":
        h = norm(params["ln1"], x)
        y, tm = ssm_lib.rwkv6_time_mix(params["time_mix"], h,
                                       head_size=min(64, cfg.d_model), state=cache["tm"])
        x = x + y
        h = norm(params["ln2"], x)
        y, cm = ssm_lib.rwkv6_channel_mix(params["channel_mix"], h, state=cache["cm"])
        return x + y, {"tm": tm, "cm": cm}

    window = window_override if window_override is not None else _resolve_window(cfg, is_global)
    if window_override == "full":
        window = None
    if group == "hymba":
        h = norm(params["ln1"], x)
        a, attn_cache = attn.gqa_decode(params["attn"], cache["attn"], h, cur_index,
                                        window=window, rope_theta=cfg.rope_theta)
        s, mamba_state = ssm_lib.mamba_apply(params["mamba"], h, state=cache["mamba"])
        x = x + 0.5 * (norm(params["ln_a"], a) + norm(params["ln_s"], s))
        x = x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act)
        return x, {"attn": attn_cache, "mamba": mamba_state}

    h = norm(params["ln1"], x)
    if cfg.attn_kind == "mla":
        a, new_cache = attn.mla_decode(params["attn"], cache, h, cur_index,
                                       qk_nope=cfg.qk_nope_head_dim,
                                       qk_rope=cfg.qk_rope_head_dim,
                                       rope_theta=cfg.rope_theta)
    else:
        a, new_cache = attn.gqa_decode(params["attn"], cache, h, cur_index,
                                       window=window, rope_theta=cfg.rope_theta)
    x = x + a
    if group == "moe":
        y, _ = moe_lib.moe_apply(params["moe"], norm(params["ln2"], x),
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor, act=cfg.act)
        return x + y, new_cache
    return x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act), new_cache


def _dec_block_decode(cfg: ArchConfig, params, cache, x, cur_index, enc_out):
    _, norm = _norm(cfg)
    h = norm(params["ln1"], x)
    a, new_cache = attn.gqa_decode(params["attn"], cache, h, cur_index,
                                   rope_theta=cfg.rope_theta)
    x = x + a
    h = norm(params["ln_x"], x)
    x = x + attn.gqa_attention(params["xattn"], h,
                               jnp.full((1,), cur_index, jnp.int32),
                               causal=False, kv_x=enc_out,
                               kv_positions=jnp.arange(enc_out.shape[1]),
                               use_rope=False, chunk=cfg.attn_chunk)
    return x + mlp(params["mlp"], norm(params["ln2"], x), act=cfg.act), new_cache


def decode_step(cfg: ArchConfig, params, cache, tokens, cur_index):
    """One decode step. tokens: (b, 1) int32; returns (logits (b, vocab), cache)."""
    x = embed(params["embed"], tokens)
    new_cache: Dict[str, Any] = dict(cache)

    if cfg.is_encoder_decoder:
        enc_out = cache["enc_out"]

        def body(h, scan_in):
            p, c = scan_in
            h2, c2 = _dec_block_decode(cfg, p, c, h, cur_index, enc_out)
            return h2, c2

        x, new_dec = lax.scan(body, x, (params["groups"]["dec"], cache["dec"]))
        new_cache["dec"] = new_dec
    else:
        for name, count, _ in layer_groups(cfg):
            if count == 0:
                continue
            if name == "lg_super":
                period = cfg.local_global_period

                def body_super(h, scan_in):
                    p, c = scan_in
                    new_c = []
                    for i in range(period):
                        sub_p = jax.tree.map(lambda t: t[i], p)
                        sub_c = jax.tree.map(lambda t: t[i], c)
                        win = "full" if cfg.layer_is_global(i) else cfg.window
                        h, c2 = _block_decode(cfg, "dense", sub_p, sub_c, h,
                                              cur_index, None, window_override=win)
                        new_c.append(c2)
                    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_c)
                    return h, stacked

                x, new_c = lax.scan(body_super, x, (params["groups"][name], cache[name]))
                new_cache[name] = new_c
                continue
            if name == "lg_tail":
                def body_tail(h, scan_in):
                    p, c = scan_in
                    h2, c2 = _block_decode(cfg, "dense", p, c, h, cur_index, None,
                                           window_override=cfg.window)
                    return h2, c2

                x, new_c = lax.scan(body_tail, x, (params["groups"][name], cache[name]))
                new_cache[name] = new_c
                continue

            def body(h, scan_in, _n=name):
                p, c, g = scan_in
                h2, c2 = _block_decode(cfg, _n, p, c, h, cur_index, g)
                return h2, c2

            xs_global = jnp.zeros((count,), bool)
            x, new_c = lax.scan(body, x, (params["groups"][name], cache[name], xs_global))
            new_cache[name] = new_c

    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
    else:
        logits = unembed(params["unembed"], x)
    return logits[:, 0, :], new_cache


def encode_for_decode(cfg: ArchConfig, params, frontend: jnp.ndarray):
    """Run the encoder once; result is stored in the decode cache (enc-dec)."""
    fe = jnp.einsum("bfd,de->bfe", frontend, params["frontend_proj"]["w"])
    pos = jnp.arange(fe.shape[1])
    enc_x, _ = _scan_group(
        lambda p, h, e: _block_apply(cfg, "enc", p, h, pos, e),
        params["groups"]["enc"], fe, remat=False)
    return enc_x
