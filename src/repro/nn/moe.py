"""Mixture-of-Experts: top-k routing with capacity buffers + shared experts.

Design (MaxText/GShard-style, adapted to avoid giant one-hot dispatch
tensors): tokens are *scatter*ed into a per-expert capacity buffer
``(E, C, d)`` using integer indices (position-in-expert via cumsum), the
expert FFNs run as one batched einsum over the expert axis, and results are
*gather*ed back and combined with the router gates.  Tokens routed past an
expert's capacity are dropped for that expert (standard GShard semantics);
the load-balance auxiliary loss keeps the router near-uniform.

Sharding: expert-indexed weights and the capacity buffer carry a leading
``expert`` logical axis mapped to the ``model`` mesh axis (expert
parallelism).  Under pjit the scatter/gather across the token (data) and
expert (model) shardings lowers to all-to-all style collectives — exactly
the communication the roofline analysis attributes to MoE layers.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import _act, mlp, mlp_template
from repro.nn.param import ParamDef


def moe_template(
    d: int,
    d_ff_expert: int,
    n_experts: int,
    *,
    n_shared: int = 0,
    gated: bool = True,
    dtype=jnp.float32,
) -> Dict[str, Any]:
    t: Dict[str, Any] = {
        "router": ParamDef((d, n_experts), ("fsdp", None), init="scaled", dtype=jnp.float32),
        "wi": ParamDef((n_experts, d, d_ff_expert), ("expert", "fsdp", None), init="scaled", dtype=dtype),
        "wo": ParamDef((n_experts, d_ff_expert, d), ("expert", None, "fsdp"), init="scaled", dtype=dtype),
    }
    if gated:
        t["wg"] = ParamDef((n_experts, d, d_ff_expert), ("expert", "fsdp", None), init="scaled", dtype=dtype)
    if n_shared:
        t["shared"] = mlp_template(d, n_shared * d_ff_expert, gated=gated, dtype=dtype)
    return t


def capacity(tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k * factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(
    params,
    x: jnp.ndarray,               # (b, s, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (b, s, d), aux load-balance loss (scalar))."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    cap = capacity(t, top_k, e, capacity_factor)

    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (t, e)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)         # (t, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert, in token order
    flat_expert = expert_idx.reshape(-1)                        # (t*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)    # (t*k, e)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)       # exclusive cumsum
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = slot < cap

    # scatter tokens into (e, cap, d) buffers
    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    src = jnp.repeat(xf, top_k, axis=0)                         # (t*k, d) token per slot
    safe_e = jnp.where(keep, flat_expert, 0)
    safe_s = jnp.where(keep, slot, 0)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[safe_e, safe_s].add(src, mode="drop")

    # expert FFN over the expert axis (one batched einsum chain)
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    if "wg" in params:
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
        h = _act(act)(g) * h
    else:
        h = _act(act)(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])       # (e, cap, d)

    # gather back + gate combine
    gathered = out_buf[safe_e, safe_s]                          # (t*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    gates = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    y = (gathered * gates).reshape(t, top_k, d).sum(axis=1)

    if "shared" in params:
        y = y + mlp(params["shared"], xf, act=act)

    # GShard load-balance loss: e * sum_e (frac tokens to e) * (mean prob e)
    frac = jnp.mean(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(1), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac / top_k * mean_prob)

    return y.reshape(b, s, d), aux
