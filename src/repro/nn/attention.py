"""Attention: GQA / MLA / sliding-window / local-global, prefill + decode.

Two compute paths, both pure ``jax.numpy`` (they lower on any backend — the
Pallas flash kernel in :mod:`repro.kernels.flash_attention` is the TPU
drop-in for the prefill path and is validated against these):

* :func:`blockwise_attention` — ``lax.scan`` over KV chunks with an online
  (running max / running sum) softmax.  Activation memory is
  O(q_len * chunk) instead of O(q_len * kv_len), which is what makes the
  32k-prefill shapes lowerable; masks are predicates over index iotas, so a
  traced ``window`` covers full-causal, sliding-window and gemma-style
  local/global layers with one code path.

* :func:`decode_attention` — single-token query against a KV cache with a
  length + window mask.  One einsum pair; for 500k-token caches this is
  memory-bound and is the shape the roofline analysis flags.

GQA is computed by grouping query heads over KV heads (no KV repetition is
materialized).  MLA (DeepSeek-V2) keeps the compressed ``c_kv`` as the
decode cache and uses the *absorbed* formulation for decode.
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.layers import apply_rope
from repro.nn.param import ParamDef

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Context parallelism (sequence-sharded attention) — §Perf optimization.
#
# Archs whose head counts don't divide the model axis (gemma3: 4 q / 1 kv
# head on a 16-wide axis) otherwise run attention fully replicated across
# that axis.  When enabled (build_prefill_step / build_train_step opt-in),
# the query tensor is sharding-constrained along its *sequence* dim so each
# model-axis slice computes 1/16th of the score rows (KV stays whole — an
# all-gather of K/V per layer, tiny next to the S^2 savings).
# --------------------------------------------------------------------------

_CONTEXT_PARALLEL: dict = {"spec": None}


@contextlib.contextmanager
def context_parallel(batch_axes, seq_axis="model"):
    """Enable sequence-sharded attention inside this context (ambient mesh)."""
    from jax.sharding import PartitionSpec
    prev = _CONTEXT_PARALLEL["spec"]
    _CONTEXT_PARALLEL["spec"] = PartitionSpec(batch_axes, seq_axis, None, None)
    try:
        yield
    finally:
        _CONTEXT_PARALLEL["spec"] = prev


def _maybe_seq_shard(q):
    spec = _CONTEXT_PARALLEL["spec"]
    if spec is None:
        return q
    return jax.lax.with_sharding_constraint(q, spec)


# --------------------------------------------------------------------------
# Core: blockwise online-softmax attention (prefill / training)
# --------------------------------------------------------------------------


def _allowed_mask(q_pos, k_pos, *, causal: bool, window):
    """(q, k) bool mask from position iotas; `window` may be traced."""
    allowed = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        allowed &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        allowed &= k_pos[None, :] > (q_pos[:, None] - window)
    return allowed


def blockwise_attention(
    q: jnp.ndarray,              # (b, sq, H, hd)
    k: jnp.ndarray,              # (b, sk, KV, hd)
    v: jnp.ndarray,              # (b, sk, KV, hdv)
    *,
    causal: bool = True,
    window: Optional[Any] = None,    # int, traced scalar, or None
    q_positions: Optional[jnp.ndarray] = None,
    k_positions: Optional[jnp.ndarray] = None,
    chunk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    sk, kv, hdv = k.shape[1], k.shape[2], v.shape[3]
    group = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    q_pos = q_positions if q_positions is not None else jnp.arange(sq)
    k_pos = k_positions if k_positions is not None else jnp.arange(sk)

    chunk = min(chunk, sk)
    n_chunks, rem = divmod(sk, chunk)
    if rem:  # pad KV to a chunk multiple; padded keys are masked out
        pad = chunk - rem
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max - 1)
        n_chunks += 1

    qg = q.reshape(b, sq, kv, group, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, n_chunks, chunk, kv, hd).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, kv, hdv).astype(jnp.float32)
    kpos_c = k_pos.reshape(n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry                     # (b,sq,kv,g), (b,sq,kv,g), (b,sq,kv,g,hdv)
        kb, vb, kp = xs                       # (b,chunk,kv,hd), (b,chunk,kv,hdv), (chunk,)
        logits = jnp.einsum("bqngd,bcnd->bqngc", qg, kb)  # (b,sq,kv,g,chunk)
        allowed = _allowed_mask(q_pos, kp, causal=causal, window=window)
        lg = jnp.where(allowed[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
        p = jnp.exp(lg - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqngc,bcne->bqnge", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kv, group), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, sq, kv, group), dtype=jnp.float32)
    a0 = jnp.zeros((b, sq, kv, group, hdv), dtype=jnp.float32)
    xs = (
        jnp.moveaxis(kc, 1, 0),   # (n_chunks, b, chunk, kv, hd)
        jnp.moveaxis(vc, 1, 0),
        kpos_c,
    )
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hdv).astype(q.dtype)


def banded_attention(
    q: jnp.ndarray,              # (b, s, H, hd)
    k: jnp.ndarray,              # (b, s, KV, hd)
    v: jnp.ndarray,              # (b, s, KV, hdv)
    *,
    window: int,                 # STATIC sliding window (causal)
    q_chunk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Sliding-window attention that only *computes* the band (§Perf).

    ``blockwise_attention`` with a window still scores every (q, kv) chunk
    pair and masks; this version gathers, per q chunk, only the KV span
    ``[chunk_end - window - q_chunk, chunk_end)`` — compute and traffic drop
    from O(S^2) to O(S * (window + q_chunk)).  All chunks are batched (no
    scan), so a context-parallel sharding on the chunk dim still parallelizes
    across the model axis.  Requires static ``window`` and s % q_chunk == 0.
    """
    b, s, h, hd = q.shape
    kv, hdv = k.shape[2], v.shape[3]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, s)
    if s % q_chunk:
        raise ValueError(f"seq {s} must divide q_chunk {q_chunk}")
    n_ch = s // q_chunk
    span = min(q_chunk + -(-window // q_chunk) * q_chunk, s)
    starts = jnp.maximum(0, (jnp.arange(n_ch) + 1) * q_chunk - span)   # (n_ch,)

    def take_span(x, st):
        return lax.dynamic_slice_in_dim(x, st, span, axis=1)

    k_sp = jax.vmap(lambda st: take_span(k, st), out_axes=1)(starts)   # (b, n_ch, span, kv, hd)
    v_sp = jax.vmap(lambda st: take_span(v, st), out_axes=1)(starts)
    qc = q.reshape(b, n_ch, q_chunk, kv, g, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bmqngd,bmcnd->bmngqc", qc, k_sp.astype(jnp.float32))

    q_pos = (jnp.arange(n_ch) * q_chunk)[:, None] + jnp.arange(q_chunk)[None]  # (n_ch, qc)
    k_pos = starts[:, None] + jnp.arange(span)[None]                           # (n_ch, span)
    allowed = (k_pos[:, None, :] <= q_pos[:, :, None]) \
        & (k_pos[:, None, :] > q_pos[:, :, None] - window)                     # (n_ch, qc, span)
    logits = jnp.where(allowed[None, :, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bmngqc,bmcne->bmqnge", p, v_sp.astype(jnp.float32))
    return out.reshape(b, s, h, hdv).astype(q.dtype)


# --------------------------------------------------------------------------
# Core: single-token decode against a KV cache
# --------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,              # (b, 1, H, hd)
    k_cache: jnp.ndarray,        # (b, S, KV, hd)
    v_cache: jnp.ndarray,        # (b, S, KV, hdv)
    cur_index,                   # scalar: position of the new token
    *,
    window: Optional[Any] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, _, h, hd = q.shape
    s, kv, hdv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[3]
    group = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(b, kv, group, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bngd,bsnd->bngs", qg, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(s)
    allowed = k_pos <= cur_index
    if window is not None:
        allowed &= k_pos > (cur_index - window)
    logits = jnp.where(allowed[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsne->bnge", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hdv).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer (full / SWA / local-global are mask choices)
# --------------------------------------------------------------------------


def gqa_template(d: int, n_heads: int, n_kv: int, head_dim: int, dtype=jnp.float32,
                 v_head_dim: Optional[int] = None) -> Dict[str, ParamDef]:
    hdv = v_head_dim or head_dim
    return {
        "wq": ParamDef((d, n_heads, head_dim), ("fsdp", "tp", None), init="scaled", dtype=dtype),
        "wk": ParamDef((d, n_kv, head_dim), ("fsdp", "tp", None), init="scaled", dtype=dtype),
        "wv": ParamDef((d, n_kv, hdv), ("fsdp", "tp", None), init="scaled", dtype=dtype),
        "wo": ParamDef((n_heads, hdv, d), ("tp", None, "fsdp"), init="scaled", dtype=dtype),
    }


def gqa_attention(
    params,
    x: jnp.ndarray,              # (b, s, d)
    positions: jnp.ndarray,      # (s,) or (b, s) -> we use (s,)
    *,
    causal: bool = True,
    window=None,
    rope_theta: float = 1e4,
    kv_x: Optional[jnp.ndarray] = None,     # cross-attention source
    kv_positions: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    chunk: int = 512,
) -> jnp.ndarray:
    src = x if kv_x is None else kv_x
    q = _maybe_seq_shard(jnp.einsum("bsd,dhk->bshk", x, params["wq"]))
    k = jnp.einsum("bsd,dnk->bsnk", src, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", src, params["wv"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        kp = kv_positions if kv_positions is not None else (positions if kv_x is None else jnp.arange(src.shape[1]))
        k = apply_rope(k, kp, rope_theta)
    # static sliding window on self-attention: compute only the band
    if (kv_x is None and causal and isinstance(window, int) and window
            and x.shape[1] % min(chunk, x.shape[1]) == 0 and window < x.shape[1]):
        out = banded_attention(q, k, v, window=window, q_chunk=chunk)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    out = blockwise_attention(
        q, k, v,
        causal=causal and kv_x is None,
        window=window,
        q_positions=positions,
        k_positions=kv_positions if kv_positions is not None else (positions if kv_x is None else jnp.arange(src.shape[1])),
        chunk=chunk,
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def gqa_init_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                   v_head_dim: Optional[int] = None, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    hdv = v_head_dim or head_dim
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hdv), dtype),
    }


def gqa_decode(
    params,
    cache: Dict[str, jnp.ndarray],
    x: jnp.ndarray,              # (b, 1, d) — the new token's activations
    cur_index,                   # scalar int: its position
    *,
    window=None,
    rope_theta: float = 1e4,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    pos = jnp.full((1,), cur_index, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    if use_rope:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cur_index, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cur_index, axis=1)
    out = decode_attention(q, k_cache, v_cache, cur_index, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k_cache, "v": v_cache}


def gqa_cross_decode(params, enc_kv: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention during decode: static precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    out = decode_attention(q, enc_kv["k"], enc_kv["v"], enc_kv["k"].shape[1] - 1)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# --------------------------------------------------------------------------


def mla_template(
    d: int, n_heads: int, *, kv_lora: int, q_lora: int,
    qk_nope: int, qk_rope: int, v_head: int, dtype=jnp.float32,
) -> Dict[str, ParamDef]:
    t: Dict[str, ParamDef] = {
        "wdkv": ParamDef((d, kv_lora), ("fsdp", None), init="scaled", dtype=dtype),
        "wkr": ParamDef((d, qk_rope), ("fsdp", None), init="scaled", dtype=dtype),
        "wuk": ParamDef((kv_lora, n_heads, qk_nope), (None, "tp", None), init="scaled", dtype=dtype),
        "wuv": ParamDef((kv_lora, n_heads, v_head), (None, "tp", None), init="scaled", dtype=dtype),
        "wo": ParamDef((n_heads, v_head, d), ("tp", None, "fsdp"), init="scaled", dtype=dtype),
    }
    if q_lora:
        t["wdq"] = ParamDef((d, q_lora), ("fsdp", None), init="scaled", dtype=dtype)
        t["wuq"] = ParamDef((q_lora, n_heads, qk_nope + qk_rope), (None, "tp", None), init="scaled", dtype=dtype)
    else:
        t["wq"] = ParamDef((d, n_heads, qk_nope + qk_rope), ("fsdp", "tp", None), init="scaled", dtype=dtype)
    return t


def _mla_q(params, x, positions, qk_nope, qk_rope, rope_theta):
    if "wdq" in params:
        q = jnp.einsum("bsd,dr->bsr", x, params["wdq"])
        q = jnp.einsum("bsr,rhk->bshk", q, params["wuq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_attention(
    params, x, positions, *, qk_nope: int, qk_rope: int,
    rope_theta: float = 1e4, chunk: int = 512,
) -> jnp.ndarray:
    """Prefill/training path: expand c_kv to per-head K/V, blockwise core."""
    q_nope, q_rope = _mla_q(params, x, positions, qk_nope, qk_rope, rope_theta)
    q_nope = _maybe_seq_shard(q_nope)
    c = jnp.einsum("bsd,dr->bsr", x, params["wdkv"])                 # compressed kv
    k_rope = apply_rope(jnp.einsum("bsd,dk->bsk", x, params["wkr"])[:, :, None, :], positions, rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, params["wuk"])
    v = jnp.einsum("bsr,rhe->bshe", c, params["wuv"])
    h = q_nope.shape[2]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], qk_rope))], axis=-1)
    scale = 1.0 / math.sqrt(qk_nope + qk_rope)
    out = blockwise_attention(q, k, v, causal=True, q_positions=positions,
                              k_positions=positions, chunk=chunk, scale=scale)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def mla_init_cache(batch: int, max_len: int, kv_lora: int, qk_rope: int, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, max_len, kv_lora), dtype),
        "kr": jnp.zeros((batch, max_len, qk_rope), dtype),
    }


def mla_decode(
    params, cache, x, cur_index, *, qk_nope: int, qk_rope: int, rope_theta: float = 1e4,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed decode: cache holds (c_kv, k_rope) only — the MLA win.

    logits_h(s) = <q_nope_h W_uk_h, c_s> + <q_rope_h, k_rope_s>
    out_h       = (sum_s p_h(s) c_s) W_uv_h
    """
    pos = jnp.full((1,), cur_index, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(params, x, pos, qk_nope, qk_rope, rope_theta)
    c_new = jnp.einsum("bsd,dr->bsr", x, params["wdkv"])
    kr_new = apply_rope(jnp.einsum("bsd,dk->bsk", x, params["wkr"])[:, :, None, :], pos, rope_theta)[:, :, 0, :]
    c_cache = lax.dynamic_update_slice_in_dim(cache["c"], c_new.astype(cache["c"].dtype), cur_index, axis=1)
    kr_cache = lax.dynamic_update_slice_in_dim(cache["kr"], kr_new.astype(cache["kr"].dtype), cur_index, axis=1)

    # absorbed path in f32: (q W_uk) c reassociates the prefill product
    # q (W_uk c); bf16 rounding would visibly diverge from the parallel path
    f32 = jnp.float32
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope.astype(f32), params["wuk"].astype(f32))
    scale = 1.0 / math.sqrt(qk_nope + qk_rope)
    logits = (
        jnp.einsum("bthr,bsr->bths", q_c, c_cache.astype(f32))
        + jnp.einsum("bthk,bsk->bths", q_rope.astype(f32), kr_cache.astype(f32))
    ) * scale
    s = c_cache.shape[1]
    allowed = jnp.arange(s) <= cur_index
    logits = jnp.where(allowed[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bths,bsr->bthr", p, c_cache.astype(jnp.float32))   # weighted c
    out = jnp.einsum("bthr,rhe->bthe", ctx, params["wuv"].astype(jnp.float32))
    y = jnp.einsum("bthe,hed->btd", out.astype(x.dtype), params["wo"])
    return y, {"c": c_cache, "kr": kr_cache}
