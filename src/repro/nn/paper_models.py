"""The paper's own experiment models.

* MNIST model (§7.4.3): a deep fully-connected network — 20 hidden layers
  of 50 ReLU units + 10-way softmax head.
* CIFAR model (§5): CNN with conv32-conv32-pool, conv64-conv64-pool,
  dense-512, softmax (ReLU activations).

These run the paper-figure benchmarks on the synthetic datasets in
:mod:`repro.data.synthetic` (no MNIST/CIFAR files in this offline
container; DESIGN.md §6 records the substitution).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn.param import ParamDef

PyTree = Any


def mlp_classifier_template(
    in_dim: int, n_classes: int, *, width: int = 50, depth: int = 20, dtype=jnp.float32
) -> Dict[str, Any]:
    layers = {}
    d = in_dim
    for i in range(depth):
        layers[f"h{i}"] = {
            "w": ParamDef((d, width), (None, None), init="scaled", scale=1.4, dtype=dtype),
            "b": ParamDef((width,), (None,), init="zeros", dtype=dtype),
        }
        d = width
    layers["out"] = {
        "w": ParamDef((d, n_classes), (None, None), init="scaled", dtype=dtype),
        "b": ParamDef((n_classes,), (None,), init="zeros", dtype=dtype),
    }
    return layers


def mlp_classifier_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (b, in_dim) -> logits (b, n_classes)."""
    h = x
    i = 0
    while f"h{i}" in params:
        p = params[f"h{i}"]
        h = jax.nn.relu(h @ p["w"] + p["b"])
        i += 1
    p = params["out"]
    return h @ p["w"] + p["b"]


def cnn_classifier_template(
    hw: int = 32, channels: int = 3, n_classes: int = 10, dtype=jnp.float32
) -> Dict[str, Any]:
    """The paper's CIFAR CNN (2xconv32, pool, 2xconv64, pool, dense512)."""

    def conv(cin, cout):
        return {
            "w": ParamDef((3, 3, cin, cout), (None, None, None, None), init="conv_scaled", dtype=dtype),
            "b": ParamDef((cout,), (None,), init="zeros", dtype=dtype),
        }

    flat = (hw // 4) * (hw // 4) * 64
    return {
        "c1": conv(channels, 32),
        "c2": conv(32, 32),
        "c3": conv(32, 64),
        "c4": conv(64, 64),
        "fc": {
            "w": ParamDef((flat, 512), (None, None), init="scaled", dtype=dtype),
            "b": ParamDef((512,), (None,), init="zeros", dtype=dtype),
        },
        "out": {
            "w": ParamDef((512, n_classes), (None, None), init="scaled", dtype=dtype),
            "b": ParamDef((n_classes,), (None,), init="zeros", dtype=dtype),
        },
    }


def _conv(p, x):
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + p["b"])


def _maxpool(x):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_classifier_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (b, h, w, c) -> logits."""
    x = _conv(params["c1"], x)
    x = _maxpool(_conv(params["c2"], x))
    x = _conv(params["c3"], x)
    x = _maxpool(_conv(params["c4"], x))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc"]["w"] + params["fc"]["b"])
    return x @ params["out"]["w"] + params["out"]["b"]


def classifier_loss(apply_fn, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = apply_fn(params, batch["x"])
    labels = batch["y"]
    logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}
