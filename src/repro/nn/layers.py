"""Basic NN layers as pure functions + their parameter templates.

Convention: every ``*_template`` returns a dict of :class:`ParamDef`, and
the matching ``apply`` function consumes the materialized dict.  Logical
sharding axes (resolved per execution mode in repro.launch.sharding):

* ``tp``   — tensor-parallel dims (heads, FFN hidden, vocab) -> ``model``,
* ``fsdp`` — d_model dims, sharded over ``data`` in serve / hierarchical
  modes (ZeRO-style), replicated in paper-faithful training,
* ``agent``/``expert``/``layers`` — see repro.nn.param.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.param import ParamDef

PyTree = Any


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rmsnorm_template(d: int, dtype=jnp.float32) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), (None,), init="ones", dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_template(d: int, dtype=jnp.float32) -> Dict[str, ParamDef]:
    return {
        "scale": ParamDef((d,), (None,), init="ones", dtype=dtype),
        "bias": ParamDef((d,), (None,), init="zeros", dtype=dtype),
    }


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_template, rmsnorm
    if kind == "layernorm":
        return layernorm_template, layernorm
    raise ValueError(f"unknown norm {kind!r}")


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def embedding_template(vocab: int, d: int, dtype=jnp.float32) -> Dict[str, ParamDef]:
    return {"table": ParamDef((vocab, d), ("tp", "fsdp"), init="embed", dtype=dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_template(d: int, vocab: int, dtype=jnp.float32) -> Dict[str, ParamDef]:
    return {"w": ParamDef((d, vocab), ("fsdp", "tp"), init="scaled", dtype=dtype)}


def unembed(params, x):
    return jnp.einsum("...d,dv->...v", x, params["w"])


# --------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain)
# --------------------------------------------------------------------------


def mlp_template(d: int, ff: int, *, gated: bool = True, dtype=jnp.float32) -> Dict[str, ParamDef]:
    t = {
        "wi": ParamDef((d, ff), ("fsdp", "tp"), init="scaled", dtype=dtype),
        "wo": ParamDef((ff, d), ("tp", "fsdp"), init="scaled", dtype=dtype),
    }
    if gated:
        t["wg"] = ParamDef((d, ff), ("fsdp", "tp"), init="scaled", dtype=dtype)
    return t


def _act(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def mlp(params, x, *, act: str = "silu"):
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if "wg" in params:
        h = _act(act)(jnp.einsum("...d,df->...f", x, params["wg"])) * h
    else:
        h = _act(act)(h)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., s, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
