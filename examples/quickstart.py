"""Quickstart: 5 agents collaboratively train a classifier with CDMSGD.

This is the paper's base setting (5 agents, fully-connected topology,
uniform agent-interaction matrix, mini-batches, fixed step) on the
synthetic stand-in dataset.  Runs in ~30s on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer, train_loop
from repro.data import AgentPartitioner, make_classification
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params


def main():
    # 1. data, distributed across 5 agents (each sees only its shard)
    train, val = make_classification(4096, n_classes=10, dim=64, seed=0)
    part = AgentPartitioner(train, n_agents=5, seed=0)

    # 2. the model (paper's MNIST-style deep MLP, narrowed for CPU)
    params = init_params(mlp_classifier_template(64, 10, width=50, depth=6),
                         jax.random.PRNGKey(0))

    # 3. fixed topology + consensus optimizer (paper Algorithm 2)
    topology = make_topology("fully_connected", 5)
    optimizer = make_optimizer("cdmsgd", 0.05, mu=0.9)

    loss = functools.partial(classifier_loss, mlp_classifier_apply)
    trainer = CollaborativeTrainer(loss, params, topology, optimizer)

    # what one consensus step costs on the wire, per exchange precision
    from repro.core.consensus import describe_exchange_cost
    for exch in ("f32", "int8"):
        print(describe_exchange_cost(trainer.state.params, topology, exch))

    # 4. train: each step = local gradient + Pi-mixing with neighbors
    train_loop(trainer, part.batches(64), n_steps=200, log_every=25, printer=print)

    # 5. evaluate every agent's model + the consensus (mean) model
    ev = trainer.evaluate({"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)})
    print(f"\nvalidation accuracy (mean over agents): {ev['acc_mean']:.4f}")
    print(f"accuracy variance across agents:        {ev['acc_var']:.2e}")
    print(f"final consensus error:                  "
          f"{trainer.history.last('consensus_error'):.3e}")


if __name__ == "__main__":
    main()
