"""Topology study (paper Fig. 2): network size + sparsity trade-offs.

Sweeps agent counts and graph topologies, printing convergence speed,
final accuracy, spectral gap, and consensus stability — the paper's
"interesting relation between convergence and topology of the graph".

    PYTHONPATH=src python examples/topology_study.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer, train_loop
from repro.data import AgentPartitioner, make_classification
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


def run_one(topology_name, n_agents, steps=120):
    train, val = make_classification(4096, n_classes=10, dim=64, seed=0)
    part = AgentPartitioner(train, n_agents, seed=0)
    params = init_params(mlp_classifier_template(64, 10, width=50, depth=6),
                         jax.random.PRNGKey(0))
    topo = make_topology(topology_name, n_agents)
    tr = CollaborativeTrainer(LOSS, params, topo, make_optimizer("cdmsgd", 0.05, mu=0.9))
    train_loop(tr, part.batches(64), steps)
    ev = tr.evaluate({"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)})
    half_acc = tr.history.series("acc")[steps // 2 - 1]
    from repro.core.consensus import exchange_bytes_per_step
    from repro.core.flatbuf import make_flat_spec
    spec = make_flat_spec(tr.state.params, lead=1)
    return {
        "lambda2": topo.lambda2,
        "gap": topo.spectral_gap,
        "half_acc": half_acc,
        "val_acc": ev["acc_mean"],
        "acc_var": ev["acc_var"],
        "consensus": tr.history.last("consensus_error"),
        "degree": topo.degree(),
        "wire_f32": exchange_bytes_per_step(spec, topo, "f32")["per_step_bytes"],
        "wire_int8": exchange_bytes_per_step(spec, topo, "int8")["per_step_bytes"],
    }


def main():
    print("== network size (fully connected, paper Fig 2a) ==")
    print(f"{'N':>4} {'mid-train acc':>14} {'final val':>10} {'consensus':>11}")
    for n in (2, 4, 8, 16):
        r = run_one("fully_connected", n)
        print(f"{n:>4} {r['half_acc']:>14.4f} {r['val_acc']:>10.4f} {r['consensus']:>11.3e}")

    print("\n== topology sparsity at N=8 (paper Fig 2b) ==")
    print(f"{'topology':>16} {'deg':>4} {'lambda2':>8} {'val acc':>8} "
          f"{'acc var':>10} {'consensus':>11} {'wire f32':>10} {'int8':>10}")
    for name in ("fully_connected", "torus", "ring", "chain"):
        r = run_one(name, 8)
        print(f"{name:>16} {r['degree']:>4} {r['lambda2']:>8.3f} {r['val_acc']:>8.4f} "
              f"{r['acc_var']:>10.2e} {r['consensus']:>11.3e} "
              f"{r['wire_f32']:>10,} {r['wire_int8']:>10,}")
    print("\npaper's claim: sparser graph (higher lambda2) -> faster average "
          "convergence,\nbut less stable consensus (higher accuracy variance).")


if __name__ == "__main__":
    main()
