"""Topology study (paper Fig. 2): network size + sparsity trade-offs.

Sweeps agent counts and graph topologies, printing convergence speed,
final accuracy, spectral gap, and consensus stability — the paper's
"interesting relation between convergence and topology of the graph" —
plus a MIXING-STRATEGY sweep (static ring vs alternating B-connected vs
multi-round i-CDSGD vs gossip pairs): the spectral-gap-vs-wire-bytes
trade-off from ``TopologySchedule.diagnostics`` that the follow-up paper
(1805.12120) calls the consensus-optimality trade-off.

    PYTHONPATH=src python examples/topology_study.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import make_topology, make_optimizer
from repro.core.topology import fixed_schedule, make_topology_schedule
from repro.core.trainer import CollaborativeTrainer, train_loop
from repro.data import AgentPartitioner, make_classification
from repro.nn.paper_models import (
    classifier_loss,
    mlp_classifier_apply,
    mlp_classifier_template,
)
from repro.nn.param import init_params

LOSS = functools.partial(classifier_loss, mlp_classifier_apply)


def run_one(topology_name, n_agents, steps=120, **mixing_kw):
    train, val = make_classification(4096, n_classes=10, dim=64, seed=0)
    part = AgentPartitioner(train, n_agents, seed=0)
    params = init_params(mlp_classifier_template(64, 10, width=50, depth=6),
                         jax.random.PRNGKey(0))
    topo = make_topology(topology_name, n_agents)
    tr = CollaborativeTrainer(LOSS, params, topo,
                              make_optimizer("cdmsgd", 0.05, mu=0.9,
                                             **({"fused": True} if mixing_kw
                                                else {})),
                              **mixing_kw)
    train_loop(tr, part.batches(64), steps)
    ev = tr.evaluate({"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)})
    half_acc = tr.history.series("acc")[steps // 2 - 1]
    from repro.core.consensus import exchange_bytes_per_step
    from repro.core.flatbuf import make_flat_spec
    spec = make_flat_spec(tr.state.params, lead=1)
    return {
        "lambda2": topo.lambda2,
        "gap": topo.spectral_gap,
        "half_acc": half_acc,
        "val_acc": ev["acc_mean"],
        "acc_var": ev["acc_var"],
        "consensus": tr.history.last("consensus_error"),
        "degree": topo.degree(),
        "wire_f32": exchange_bytes_per_step(spec, topo, "f32")["per_step_bytes"],
        "wire_int8": exchange_bytes_per_step(spec, topo, "int8")["per_step_bytes"],
        "wire_per_step": tr.wire_bytes_per_step,
    }


# (label, base topology, trainer mixing kwargs, schedule factory)
STRATEGIES = [
    ("static ring", "ring", {},
     lambda n: fixed_schedule(make_topology("ring", n))),
    ("alternating ring/torus", "ring",
     {"mixing_strategy": "time_varying",
      "topology_schedule": "alternating:ring:torus"},
     lambda n: make_topology_schedule("alternating:ring:torus", n)),
    ("2-round ring (i-CDSGD)", "ring",
     {"mixing_strategy": "multi_round", "consensus_rounds": 2},
     lambda n: fixed_schedule(make_topology("ring", n))),
    ("gossip pairs (B-conn)", "ring",
     {"mixing_strategy": "time_varying", "topology_schedule": "gossip:8"},
     lambda n: make_topology_schedule("gossip:8", n)),
]


def strategy_sweep(n_agents=8, steps=120):
    """Spectral gap vs wire bytes across mixing strategies.

    ``eff gap`` is the schedule's per-step effective spectral gap
    (``TopologySchedule.effective_lambda2`` of the period product, with
    the round count folded in) — the quantity that replaces ``1 -
    lambda_2(Pi)`` in Proposition 1; ``wire/step`` is the amortized
    per-agent bytes the strategy puts on the wire each optimizer step.
    More gap per byte = better consensus for the bandwidth.
    """
    print(f"{'strategy':>24} {'eff gap':>8} {'deg':>5} {'wire/step':>11} "
          f"{'gap/MB':>8} {'val acc':>8} {'consensus':>11}")
    for label, topo_name, kw, sched_fn in STRATEGIES:
        sched = sched_fn(n_agents)
        rounds = kw.get("consensus_rounds", 1)
        d = sched.diagnostics(rounds)
        r = run_one(topo_name, n_agents, steps=steps, **kw)
        gap_per_mb = d["effective_gap"] / max(r["wire_per_step"] / 1e6, 1e-12)
        print(f"{label:>24} {d['effective_gap']:>8.4f} "
              f"{d['mean_degree'] * rounds:>5.1f} {r['wire_per_step']:>11,} "
              f"{gap_per_mb:>8.3f} {r['val_acc']:>8.4f} "
              f"{r['consensus']:>11.3e}")


def main():
    print("== network size (fully connected, paper Fig 2a) ==")
    print(f"{'N':>4} {'mid-train acc':>14} {'final val':>10} {'consensus':>11}")
    for n in (2, 4, 8, 16):
        r = run_one("fully_connected", n)
        print(f"{n:>4} {r['half_acc']:>14.4f} {r['val_acc']:>10.4f} {r['consensus']:>11.3e}")

    print("\n== topology sparsity at N=8 (paper Fig 2b) ==")
    print(f"{'topology':>16} {'deg':>4} {'lambda2':>8} {'val acc':>8} "
          f"{'acc var':>10} {'consensus':>11} {'wire f32':>10} {'int8':>10}")
    for name in ("fully_connected", "torus", "ring", "chain"):
        r = run_one(name, 8)
        print(f"{name:>16} {r['degree']:>4} {r['lambda2']:>8.3f} {r['val_acc']:>8.4f} "
              f"{r['acc_var']:>10.2e} {r['consensus']:>11.3e} "
              f"{r['wire_f32']:>10,} {r['wire_int8']:>10,}")
    print("\npaper's claim: sparser graph (higher lambda2) -> faster average "
          "convergence,\nbut less stable consensus (higher accuracy variance).")

    print("\n== mixing strategies at N=8 (1805.12120 consensus-optimality "
          "trade-off) ==")
    strategy_sweep(8)
    print("\ntrade-off: multi-round buys spectral gap linearly in wire "
          "bytes; a B-connected\nalternating schedule buys it from the "
          "product matrix at single-round cost; gossip\npairs minimize "
          "per-step wire at the weakest per-step mixing.")


if __name__ == "__main__":
    main()
