"""End-to-end driver: collaborative LM pre-training with CDSGD/CDMSGD.

Trains one of the ten assigned architectures collaboratively across N
agents, each holding a private shard of the token stream — the paper's
data-parallel, decentralized setting applied to a modern LM, with
checkpointing and evaluation against a held-out stream.

Scale presets:
  --scale tiny   (default) reduced config, runs on this CPU container
  --scale 100m   ~100M-param config for a few hundred steps — the
                 real-hardware run (single host with accelerators);
                 on the production mesh use repro.launch.train / dryrun.

    PYTHONPATH=src python examples/collaborative_lm_pretrain.py \
        --arch rwkv6-1.6b --agents 4 --topology ring --steps 60
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import make_topology, make_optimizer, schedules
from repro.core.trainer import CollaborativeTrainer
from repro.data import make_lm_tokens, lm_agent_batches
from repro.nn import count_params, init_params, loss_fn, model_template


def scale_config(cfg, scale: str):
    if scale == "tiny":
        return cfg.reduced()
    if scale == "100m":
        return dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m",
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=min(cfg.n_kv_heads, 12),
            head_dim=64, d_ff=3072, vocab_size=32768,
            n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
            d_ff_expert=1024 if cfg.is_moe else 0)
    raise ValueError(scale)


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--optimizer", default="cdmsgd")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--fused", action="store_true",
                    help="flat-buffer fused consensus update")
    ap.add_argument("--exchange", default="f32",
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="simulated neighbor-exchange wire precision "
                         "(implies --fused; the knob lives on the fused path)")
    ap.add_argument("--diminishing", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = scale_config(get_config(args.arch), args.scale)
    template = model_template(cfg)
    params = init_params(template, jax.random.PRNGKey(0))
    print(f"[e2e] {cfg.name}: {count_params(template):,} params | "
          f"{args.agents} agents | {args.topology} | {args.optimizer}")

    sched = (schedules.diminishing(theta=args.lr * 20, eps=1.0, t=20.0)
             if args.diminishing else args.lr)
    kw = {"mu": 0.9} if args.optimizer in ("cdmsgd", "cdmsgd_nesterov") else {}
    if args.exchange != "f32" and not args.fused:
        print(f"[e2e] --exchange {args.exchange} implies --fused; enabling")
        args.fused = True
    if args.fused:
        kw["fused"] = True
    opt = make_optimizer(args.optimizer, sched, **kw)
    topo = make_topology(args.topology, args.agents)

    def lm_loss(p, batch):
        extra = {}
        if cfg.modality in ("audio", "vlm"):
            extra["frontend"] = jnp.ones(
                (batch["inputs"].shape[0], cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        return loss_fn(cfg, p, {**batch, **extra})

    trainer = CollaborativeTrainer(lm_loss, params, topo, opt,
                                   exchange=args.exchange)

    from repro.core.consensus import describe_exchange_cost
    print("[e2e] " + describe_exchange_cost(trainer.state.params, topo,
                                            args.exchange))

    # private token shards per agent
    tokens = make_lm_tokens(1 << 16, vocab=cfg.vocab_size, seed=0)
    batches = lm_agent_batches(tokens, args.agents, args.batch, args.seq, seed=0)
    held_out = make_lm_tokens(1 << 12, vocab=cfg.vocab_size, seed=99)

    t0 = time.time()
    first_loss = None
    for i in range(args.steps):
        m = trainer.step(next(batches))
        first_loss = first_loss or m["loss"]
        if (i + 1) % 10 == 0:
            print(f"[e2e] step {i+1:>4} loss={m['loss']:.4f} "
                  f"consensus={m['consensus_error']:.3e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    # evaluate the consensus model on held-out tokens
    hb = {"inputs": jnp.asarray(held_out[None, : args.seq], jnp.int32),
          "targets": jnp.asarray(held_out[None, 1 : args.seq + 1], jnp.int32)}
    loss, _ = lm_loss(trainer.mean_params(), hb)
    print(f"[e2e] train loss {first_loss:.4f} -> {m['loss']:.4f}; "
          f"held-out (consensus model): {float(loss):.4f}")
    assert m["loss"] < first_loss, "training must reduce the loss"
    if args.ckpt:
        print("[e2e] saved:", save_checkpoint(args.ckpt, trainer.state.step,
                                              {"params": trainer.state.params}))


if __name__ == "__main__":
    main()
