"""Batched serving demo: decode from a CDSGD-trained consensus model.

Trains a tiny LM collaboratively, extracts the consensus (agent-mean)
model, then serves batched greedy-decode requests with a KV cache — the
serve path that the decode dry-run shapes lower on the production mesh.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_topology, make_optimizer
from repro.core.trainer import CollaborativeTrainer
from repro.data import make_lm_tokens, lm_agent_batches
from repro.nn import (decode_step, init_cache, init_params, loss_fn,
                      model_template)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.005)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(model_template(cfg), jax.random.PRNGKey(0))

    # 1. collaborative training (4 agents, ring)
    topo = make_topology("ring", 4)
    trainer = CollaborativeTrainer(lambda p, b: loss_fn(cfg, p, b), params, topo,
                                   make_optimizer("cdmsgd", args.lr, mu=0.9))
    tokens = make_lm_tokens(1 << 14, vocab=cfg.vocab_size, seed=0)
    batches = lm_agent_batches(tokens, 4, 4, 32, seed=0)
    for i in range(args.train_steps):
        m = trainer.step(next(batches))
    print(f"[serve] trained {args.train_steps} steps, loss={m['loss']:.3f}")

    # 2. consensus model -> batched KV-cache decoding
    serve_params = trainer.mean_params()
    max_len = args.prompt_len + args.new_tokens
    cache = init_cache(cfg, args.batch, max_len)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    prompts = np.stack([tokens[i * 100 : i * 100 + args.prompt_len]
                        for i in range(args.batch)])
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    seqs = [np.asarray(tok)]
    t0 = time.time()
    for i in range(max_len - 1):
        logits, cache = step(serve_params, cache, tok, jnp.int32(i))
        if i + 1 < args.prompt_len:
            tok = jnp.asarray(prompts[:, i + 1 : i + 2], jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        seqs.append(np.asarray(tok))
    dt = time.time() - t0
    out = np.concatenate(seqs, axis=1)
    print(f"[serve] {args.batch} requests x {max_len} tokens in {dt:.2f}s "
          f"({args.batch * max_len / dt:.1f} tok/s, CPU interpret scale)")
    for b in range(min(args.batch, 2)):
        print(f"[serve] req{b}: prompt={out[b, :args.prompt_len].tolist()} "
              f"-> {out[b, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
